//! Discrete-event primitives and the single-edge simulation entry point.
//!
//! The event engine itself lives in [`crate::cluster`]: a [`Cluster`] of N
//! [`Platform`](crate::platform::Platform)s is driven by one [`EventQueue`]
//! whose entries carry an *edge scope* tag, so a 7-edge §8.1 emulation and a
//! single-edge study run through the same deterministic loop. [`run`] here
//! is the convenience wrapper for the 1-edge case every unit study uses.
//!
//! A 300 s × 4-drone × 6-model experiment (7 200 tasks) runs in a few
//! milliseconds, which is what makes the full Fig. 8–18 reproduction sweep
//! tractable. The same platform state machine is also driven by the
//! real-time serving loop in `serve` (behind the `pjrt` feature).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cluster::{Cluster, ARRIVAL_SEED_XOR};
use crate::fleet::Workload;
use crate::metrics::Metrics;
use crate::platform::Platform;
use crate::sched::Scheduler;
use crate::task::Task;
use crate::time::{secs, Micros};

/// Platform events, ordered by virtual time.
#[derive(Clone, Debug)]
pub enum Event {
    /// A video segment tick for one drone (self-rescheduling).
    Segment { drone: u32, tick: u64 },
    /// The edge executor finished its current task.
    EdgeDone,
    /// A cloud-queue trigger time arrived.
    CloudTrigger,
    /// An in-flight FaaS invocation completed.
    CloudDone { key: u64 },
    /// A model's tumbling QoE window closed.
    WindowClose { model_idx: usize },
    /// A cross-edge stolen task arrives at its destination edge after
    /// its LAN transfer (fleet federation; scope = destination edge).
    FedArrive { task: Task },
    /// A drone re-homes to another edge (fleet handover; scope = the
    /// destination edge, which records the handover).
    Handover { drone: u32, to_edge: u32 },
    /// A pipeline successor stage arrives at its home edge for admission
    /// — pushed at the predecessor's completion time plus the wireless
    /// transfer when the handoff leaves the drone tier
    /// ([`crate::pipeline`]).
    StageArrive { task: Task },
    /// The drone's companion computer finished a pipeline prefix stage
    /// (`started` = when it began, for the exec-duration accounting).
    DroneDone { task: Task, started: Micros },
    /// A scheduled fault fires (edge crash/recovery, region outage, link
    /// flap — see [`crate::fault`]). Compiled from a
    /// [`FaultSpec`](crate::fault::FaultSpec) at cluster setup, so at
    /// equal timestamps a fault precedes handovers and every in-run event
    /// (push order breaks ties; faults are pushed first).
    Fault(crate::fault::FaultAction),
    /// The hedge delay of in-flight cloud invocation `key` elapsed: if
    /// the primary is still running, launch the speculative duplicate
    /// (see [`crate::resilience`]). Pushed only when the policy's
    /// `ResilienceSpec` enables hedging; a no-op when the primary
    /// already completed.
    HedgeFire { key: u64 },
}

struct Item {
    at: Micros,
    seq: u64,
    /// Edge scope: which platform of a cluster this event belongs to.
    scope: u32,
    event: Event,
}

impl PartialEq for Item {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Item {}
impl PartialOrd for Item {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Item {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Time-ordered event queue (min-heap, FIFO among equal timestamps).
///
/// Every pushed event is stamped with the queue's *current scope* (an edge
/// index, set by the cluster driver before dispatching into a platform), so
/// one queue can interleave N independent platforms deterministically. The
/// scope is ignored in single-edge runs; relative ordering is always
/// `(time, push order)`, never scope.
///
/// Cross-edge tie-break (audited for the fleet-federation layer): when a
/// federated event — a steal arrival, a handover — lands on the same
/// microsecond as a sibling edge's local event (a cloud trigger, an
/// `EdgeDone`), the winner is strictly whichever was *pushed first*; the
/// scope stamp never reorders. Handovers are pushed at cluster setup, so
/// a handover at `t` always precedes segment ticks at `t` (their pushes
/// chain from `t − period`); steal arrivals are pushed at steal time, so
/// they rank after any same-instant event that was already pending. This
/// order is pinned by `cross_edge_equal_timestamp_ties_break_by_push_order`
/// below — federation stays deterministic because every tie is resolved
/// by push order alone.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Item>>,
    seq: u64,
    scope: u32,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the edge scope stamped onto subsequently pushed events.
    pub fn set_scope(&mut self, scope: u32) {
        self.scope = scope;
    }

    /// Reset to the empty state (scope and FIFO tie-break counter
    /// included) while keeping the heap's allocation, so one queue can be
    /// reused across cluster runs with bit-identical results
    /// ([`crate::cluster::Cluster::run_with`]).
    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
        self.scope = 0;
    }

    pub fn push(&mut self, at: Micros, event: Event) {
        self.seq += 1;
        self.heap.push(Reverse(Item {
            at,
            seq: self.seq,
            scope: self.scope,
            event,
        }));
    }

    pub fn pop(&mut self) -> Option<(Micros, Event)> {
        self.heap.pop().map(|Reverse(i)| (i.at, i.event))
    }

    /// Pop with the edge scope the event was pushed under.
    pub fn pop_scoped(&mut self) -> Option<(Micros, u32, Event)> {
        self.heap.pop().map(|Reverse(i)| (i.at, i.scope, i.event))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// How long past the nominal duration in-flight work may settle before the
/// run is hard-drained (matches the paper counting late completions of the
/// last segments).
pub const SETTLE: Micros = secs(5);

/// Run one platform against a workload; returns the final metrics.
///
/// This is the single-edge convenience wrapper over the cluster engine: it
/// seeds the arrival stream with `seed ^ 0x5EED_F1EE7` (as every study in
/// the repo always has) and drives a one-edge [`Cluster`].
pub fn run<S: Scheduler>(platform: Platform<S>, workload: &Workload,
                         seed: u64) -> Metrics {
    let cluster = Cluster::from_parts(vec![platform], workload.clone(),
                                      vec![seed ^ ARRIVAL_SEED_XOR]);
    let mut cm = cluster.run();
    cm.per_edge.pop().expect("one edge")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_queue_orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.push(200, Event::EdgeDone);
        q.push(100, Event::CloudTrigger);
        q.push(100, Event::EdgeDone);
        let (t1, e1) = q.pop().unwrap();
        assert_eq!(t1, 100);
        assert!(matches!(e1, Event::CloudTrigger)); // pushed first at t=100
        let (t2, e2) = q.pop().unwrap();
        assert_eq!(t2, 100);
        assert!(matches!(e2, Event::EdgeDone));
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, 200);
        assert!(q.pop().is_none());
    }

    #[test]
    fn scope_is_stamped_and_recovered() {
        let mut q = EventQueue::new();
        q.set_scope(3);
        q.push(100, Event::EdgeDone);
        q.set_scope(1);
        q.push(100, Event::CloudTrigger);
        let (_, s1, e1) = q.pop_scoped().unwrap();
        assert_eq!(s1, 3);
        assert!(matches!(e1, Event::EdgeDone));
        let (_, s2, _) = q.pop_scoped().unwrap();
        assert_eq!(s2, 1);
    }

    #[test]
    fn cross_edge_equal_timestamp_ties_break_by_push_order() {
        // Federation determinism pin: a steal arrival for edge 1 pushed
        // *before* edge 0's local cloud dispatch at the same timestamp
        // pops first, and vice versa — (time, push seq) is the whole
        // order; the scope stamp never reorders equal timestamps.
        use crate::model::DnnKind;
        use crate::task::VideoSegment;
        let mktask = || Task {
            id: 1,
            model: DnnKind::Hv,
            segment: VideoSegment {
                id: 1,
                drone: 0,
                created_at: 0,
                bytes: 38_000,
            },
            pipeline: None,
        };
        let mut q = EventQueue::new();
        q.set_scope(1);
        q.push(100, Event::FedArrive { task: mktask() });
        q.set_scope(0);
        q.push(100, Event::CloudTrigger);
        let (t, s, e) = q.pop_scoped().unwrap();
        assert_eq!((t, s), (100, 1));
        assert!(matches!(e, Event::FedArrive { .. }));
        let (t, s, e) = q.pop_scoped().unwrap();
        assert_eq!((t, s), (100, 0));
        assert!(matches!(e, Event::CloudTrigger));
        // Reversed push order reverses the winner at the same instant.
        let mut q = EventQueue::new();
        q.set_scope(0);
        q.push(100, Event::CloudTrigger);
        q.set_scope(1);
        q.push(100, Event::FedArrive { task: mktask() });
        let (_, s, e) = q.pop_scoped().unwrap();
        assert_eq!(s, 0);
        assert!(matches!(e, Event::CloudTrigger));
        // And a handover pushed at setup precedes a same-instant local
        // event pushed later (the "re-home exactly at the window edge"
        // boundary).
        let mut q = EventQueue::new();
        q.set_scope(1);
        q.push(200, Event::Handover { drone: 0, to_edge: 1 });
        q.set_scope(0);
        q.push(200, Event::Segment { drone: 0, tick: 3 });
        let (_, _, e) = q.pop_scoped().unwrap();
        assert!(matches!(e, Event::Handover { .. }));
    }

    #[test]
    fn scope_does_not_affect_ordering() {
        let mut q = EventQueue::new();
        q.set_scope(9);
        q.push(200, Event::EdgeDone);
        q.set_scope(0);
        q.push(100, Event::EdgeDone);
        let (t, s, _) = q.pop_scoped().unwrap();
        assert_eq!((t, s), (100, 0));
        let (t, s, _) = q.pop_scoped().unwrap();
        assert_eq!((t, s), (200, 9));
    }
}
