//! DEMS-A adaptation to cloud variability (§5.4).
//!
//! Per model: a circular buffer (size `w`) of observed cloud durations. When
//! the sliding average exceeds the current expected duration by ε, the
//! expected duration is raised to the average; a cooling period bounds how
//! long a model can be locked out of the cloud before the expectation is
//! reset to its static default and re-discovery begins.

use crate::time::Micros;

/// Adaptation state for one DNN model.
#[derive(Clone, Debug)]
pub struct ModelAdapt {
    /// Static default t̂ from the profile table.
    static_expected: Micros,
    /// Current expected duration used for trigger/feasibility math.
    expected: Micros,
    /// Circular buffer of observed actual durations.
    buf: Vec<Micros>,
    head: usize,
    filled: usize,
    /// First time a task of this model was skipped for the cloud because
    /// the *adapted* expectation made it infeasible; None when not skipping.
    skip_since: Option<Micros>,
}

impl ModelAdapt {
    pub fn new(static_expected: Micros, w: usize) -> Self {
        ModelAdapt {
            static_expected,
            expected: static_expected,
            buf: vec![0; w.max(1)],
            head: 0,
            filled: 0,
            skip_since: None,
        }
    }

    /// Current expected cloud duration t̂ᵢ.
    #[inline]
    pub fn expected(&self) -> Micros {
        self.expected
    }

    pub fn is_adapted(&self) -> bool {
        self.expected != self.static_expected
    }

    /// Record an observed cloud duration; update the expectation when the
    /// sliding average exceeds it by ε (upward adaptation only — recovery
    /// happens via the cooling reset or a lower observed average after it).
    pub fn observe(&mut self, actual: Micros, epsilon: Micros) {
        self.buf[self.head] = actual;
        self.head = (self.head + 1) % self.buf.len();
        self.filled = (self.filled + 1).min(self.buf.len());
        let avg = self.average();
        if avg > self.expected + epsilon {
            self.expected = avg;
        }
        // A successful observation means the cloud is reachable again.
        self.skip_since = None;
    }

    /// Sliding-window average of the observed durations.
    pub fn average(&self) -> Micros {
        if self.filled == 0 {
            return self.expected;
        }
        let sum: u128 =
            self.buf[..self.filled].iter().map(|&v| v as u128).sum();
        (sum / self.filled as u128) as Micros
    }

    /// A task of this model was skipped for the cloud due to an expected
    /// deadline miss at time `now`. If skipping has persisted for the
    /// cooling period t_cp, reset to the static default (§5.4's "point of
    /// no return" escape) and start re-discovery.
    pub fn on_skip(&mut self, now: Micros, cooling: Micros) {
        match self.skip_since {
            None => self.skip_since = Some(now),
            Some(t0) if now.saturating_sub(t0) >= cooling => {
                self.expected = self.static_expected;
                self.filled = 0;
                self.head = 0;
                self.skip_since = None;
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::time::{ms, secs};

    #[test]
    fn starts_at_static_default() {
        let a = ModelAdapt::new(ms(400), 10);
        assert_eq!(a.expected(), ms(400));
        assert!(!a.is_adapted());
    }

    #[test]
    fn adapts_upward_when_average_exceeds_epsilon() {
        let mut a = ModelAdapt::new(ms(400), 4);
        for _ in 0..4 {
            a.observe(ms(800), ms(10));
        }
        assert_eq!(a.expected(), ms(800));
        assert!(a.is_adapted());
    }

    #[test]
    fn small_excursions_below_epsilon_ignored() {
        let mut a = ModelAdapt::new(ms(400), 4);
        for _ in 0..8 {
            a.observe(ms(405), ms(10));
        }
        assert_eq!(a.expected(), ms(400));
    }

    #[test]
    fn sliding_window_forgets_old_samples() {
        let mut a = ModelAdapt::new(ms(400), 2);
        a.observe(ms(1000), ms(10));
        a.observe(ms(1000), ms(10));
        assert_eq!(a.expected(), ms(1000));
        // Window now slides over two fast samples; average drops but the
        // expectation only moves up — until a cooling reset.
        a.observe(ms(300), ms(10));
        a.observe(ms(300), ms(10));
        assert_eq!(a.average(), ms(300));
        assert_eq!(a.expected(), ms(1000));
    }

    #[test]
    fn cooling_period_resets_to_static() {
        let mut a = ModelAdapt::new(ms(400), 4);
        for _ in 0..4 {
            a.observe(secs(5), ms(10)); // latency storm
        }
        assert!(a.is_adapted());
        a.on_skip(secs(100), secs(10)); // first skip: start the clock
        assert!(a.is_adapted());
        a.on_skip(secs(105), secs(10)); // within cooling: still locked out
        assert!(a.is_adapted());
        a.on_skip(secs(110), secs(10)); // cooling elapsed: reset
        assert!(!a.is_adapted());
        assert_eq!(a.expected(), ms(400));
    }

    #[test]
    fn successful_observation_clears_skip_clock() {
        let mut a = ModelAdapt::new(ms(400), 4);
        a.on_skip(secs(1), secs(10));
        a.observe(ms(400), ms(10));
        // Skip clock restarted: a later skip shouldn't instantly reset.
        a.on_skip(secs(20), secs(10));
        for _ in 0..4 {
            a.observe(secs(2), ms(10));
        }
        assert!(a.is_adapted());
    }
}
