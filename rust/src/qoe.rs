//! GEMS QoE window monitoring (§6, Algorithm 1).
//!
//! Per model: a tumbling window of duration ω tracks λ (tasks finishing in
//! the window) and λ̂ (those that met their deadline). After every finalized
//! task the incremental rate α̂ = λ̂/λ is compared with the required α; when
//! the model falls behind, the platform greedily reschedules its pending
//! edge tasks to the cloud (handled by the caller — this module owns only
//! the counters and window lifecycle).

use crate::time::Micros;

/// Window accounting state for one DNN model.
#[derive(Clone, Debug)]
pub struct WindowMonitor {
    /// Required completion rate αᵢ (0 disables monitoring).
    pub alpha: f64,
    /// Window duration ωᵢ.
    pub omega: Micros,
    /// QoE benefit β̄ᵢ accrued per satisfied window.
    pub qoe_benefit: f64,
    /// Window start/end (w_s, w_e].
    pub window_start: Micros,
    pub window_end: Micros,
    /// λ: tasks of this model finalized within the current window.
    pub total: u64,
    /// λ̂: of those, completed within their deadline.
    pub succeeded: u64,
    /// Accumulated QoE utility over closed windows.
    pub qoe_utility: f64,
    pub windows_total: u64,
    pub windows_met: u64,
}

impl WindowMonitor {
    pub fn new(alpha: f64, omega: Micros, qoe_benefit: f64) -> Self {
        WindowMonitor {
            alpha,
            omega,
            qoe_benefit,
            window_start: 0,
            window_end: omega,
            total: 0,
            succeeded: 0,
            qoe_utility: 0.0,
            windows_total: 0,
            windows_met: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.alpha > 0.0
    }

    /// Record a finalized task (Alg. 1 lines 3–7). Returns the incremental
    /// completion rate α̂ after the update.
    pub fn record(&mut self, success: bool) -> f64 {
        self.total += 1;
        if success {
            self.succeeded += 1;
        }
        self.rate()
    }

    /// Current incremental completion rate α̂ (1.0 while empty, so an empty
    /// window never triggers rescheduling).
    pub fn rate(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            self.succeeded as f64 / self.total as f64
        }
    }

    /// Is the model behind its target (Alg. 1 line 8)?
    pub fn falling_behind(&self) -> bool {
        self.enabled() && self.rate() < self.alpha
    }

    /// Close the current window at its end time (Alg. 1 lines 16–21):
    /// accrue β̄ when the final rate meets α, then tumble. Returns whether
    /// the window met its target.
    pub fn close_window(&mut self) -> bool {
        let met = self.total > 0 && self.rate() >= self.alpha;
        self.windows_total += 1;
        if met {
            self.qoe_utility += self.qoe_benefit;
            self.windows_met += 1;
        }
        self.window_start = self.window_end;
        self.window_end += self.omega;
        self.total = 0;
        self.succeeded = 0;
        met
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::time::secs;

    #[test]
    fn rate_tracks_successes() {
        let mut w = WindowMonitor::new(0.9, secs(20), 100.0);
        assert_eq!(w.rate(), 1.0);
        w.record(true);
        w.record(true);
        w.record(false);
        assert!((w.rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!(w.falling_behind());
    }

    #[test]
    fn not_behind_when_meeting_alpha() {
        let mut w = WindowMonitor::new(0.5, secs(20), 100.0);
        w.record(true);
        w.record(false);
        assert!(!w.falling_behind()); // exactly at 0.5
        w.record(false);
        assert!(w.falling_behind());
    }

    #[test]
    fn close_window_accrues_and_tumbles() {
        let mut w = WindowMonitor::new(0.9, secs(20), 100.0);
        for _ in 0..9 {
            w.record(true);
        }
        w.record(false);
        assert!(w.close_window()); // 0.9 meets α = 0.9
        assert_eq!(w.qoe_utility, 100.0);
        assert_eq!((w.window_start, w.window_end), (secs(20), secs(40)));
        assert_eq!(w.total, 0);
        // Next window fails.
        w.record(false);
        assert!(!w.close_window());
        assert_eq!(w.qoe_utility, 100.0);
        assert_eq!(w.windows_total, 2);
        assert_eq!(w.windows_met, 1);
    }

    #[test]
    fn empty_window_accrues_nothing() {
        let mut w = WindowMonitor::new(0.9, secs(20), 100.0);
        assert!(!w.close_window());
        assert_eq!(w.qoe_utility, 0.0);
    }

    #[test]
    fn disabled_monitor_never_behind() {
        let mut w = WindowMonitor::new(0.0, secs(20), 0.0);
        w.record(false);
        w.record(false);
        assert!(!w.falling_behind());
        assert!(!w.enabled());
    }

    #[test]
    fn multi_stage_chains_record_once_per_chain() {
        // Pipeline contract (platform::Core::finalize): a split-DNN
        // chain contributes exactly ONE sample to the final model's
        // window — the chain verdict — never one per stage. 9 of 10
        // three-stage chains completing must read α̂ = 0.9, identical
        // to 9 of 10 single-stage tasks; chain depth never inflates λ.
        let mut w = WindowMonitor::new(0.9, secs(20), 100.0);
        for chain in 0..10u32 {
            // Two intermediate successes record nothing...
            // ...and only the end-to-end verdict lands in the window.
            w.record(chain != 0);
        }
        assert_eq!((w.total, w.succeeded), (10, 9));
        assert!((w.rate() - 0.9).abs() < 1e-12);
        assert!(!w.falling_behind());
        assert!(w.close_window());
    }

    #[test]
    fn chain_kill_weighs_like_a_missed_final_stage() {
        // A chain killed at an intermediate stage records a single miss
        // in the *final* model's window (the output that never arrived),
        // so a stage-1 drop and a final-stage deadline miss are
        // indistinguishable to the frequency accounting.
        let mut w = WindowMonitor::new(0.9, secs(20), 100.0);
        w.record(false); // stage 1 of 3 dropped → chain dead, one miss
        w.record(true); // a second chain completed end-to-end
        assert_eq!((w.total, w.succeeded), (2, 1));
        assert!(w.falling_behind());
        assert!(!w.close_window());
    }
}
