//! Dependency-free scoped worker pool — the execution engine behind the
//! parallel experiment sweeps (`--jobs N`).
//!
//! The evaluation grids of §8 are embarrassingly parallel: every cell
//! (workload × policy × seed × edge spec) builds its own cluster from its
//! own seed and shares nothing with its neighbours. [`Pool::run`] exploits
//! that with plain `std::thread::scope` workers (the offline default build
//! stays zero-dependency — no rayon):
//!
//! * Jobs are sharded round-robin into per-worker deques; a worker drains
//!   its own deque front-first and, when empty, **steals from the back**
//!   of its peers', so a straggler cell (a 28-edge fig13 run next to a
//!   2-edge smoke cell) cannot leave the rest of the machine idle.
//! * Results land in per-job slots indexed by submission order, so the
//!   output `Vec` is always in enumeration order — schedule-independent,
//!   which is what keeps parallel reports **byte-identical** to the
//!   sequential path (`tests/sweep_parity.rs`).
//! * A panicking job aborts the sweep: remaining workers stop picking up
//!   jobs and the first panic payload is re-thrown to the caller after
//!   the scope joins (`worker_panics_propagate_to_the_caller`).
//!
//! `Pool::new(1)` (and single-job runs) bypass the threads entirely and
//! execute inline, so `--jobs 1` *is* the sequential engine, not an
//! emulation of it.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Worker count `Pool::new(0)` resolves to: the machine's available
/// parallelism (1 when undetectable, e.g. under exotic cgroup configs).
pub fn auto_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Lock helper that shrugs off poisoning: the shared state is plain data
/// (job indices / result slots) and the panic that poisoned it is
/// re-thrown to the caller anyway after the scope joins.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Per-worker deque of job indices (submission order).
type JobDeque = Mutex<VecDeque<usize>>;

/// First panic payload raised by any job, kept for re-throw.
type Failure = Mutex<Option<Box<dyn Any + Send>>>;

/// A fixed-width scoped worker pool. Cheap to construct (no threads are
/// kept alive between [`Pool::run`] calls — each run is one
/// `thread::scope`), so sweeps build one wherever a `jobs` knob surfaces.
pub struct Pool {
    workers: usize,
}

impl Pool {
    /// `workers == 0` means "auto" ([`auto_workers`]); `1` runs inline.
    pub fn new(workers: usize) -> Pool {
        Pool {
            workers: if workers == 0 { auto_workers() } else { workers },
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute jobs `0..n` through `f`, returning the results **in job
    /// order** regardless of the execution schedule.
    ///
    /// Panics from any job are propagated (first payload wins) after all
    /// workers have stopped.
    pub fn run<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.workers <= 1 || n <= 1 {
            // The sequential engine itself, not an emulation: same call
            // order, same thread, no synchronization.
            return (0..n).map(f).collect();
        }
        let w = self.workers.min(n);
        // Shard jobs round-robin, then wrap for sharing.
        let mut shards: Vec<VecDeque<usize>> = vec![VecDeque::new(); w];
        for i in 0..n {
            shards[i % w].push_back(i);
        }
        let deques: Vec<JobDeque> =
            shards.into_iter().map(Mutex::new).collect();
        let slots: Vec<Mutex<Option<T>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let failure: Failure = Mutex::new(None);
        let abort = AtomicBool::new(false);
        std::thread::scope(|s| {
            for me in 0..w {
                let (deques, slots, failure, abort, f) =
                    (&deques, &slots, &failure, &abort, &f);
                s.spawn(move || {
                    while let Some(i) = next_job(me, deques) {
                        if abort.load(Ordering::Relaxed) {
                            return;
                        }
                        match panic::catch_unwind(AssertUnwindSafe(|| f(i)))
                        {
                            Ok(v) => *lock(&slots[i]) = Some(v),
                            Err(payload) => {
                                abort.store(true, Ordering::Relaxed);
                                let mut first = lock(failure);
                                if first.is_none() {
                                    *first = Some(payload);
                                }
                                return;
                            }
                        }
                    }
                });
            }
        });
        if let Some(payload) = lock(&failure).take() {
            panic::resume_unwind(payload);
        }
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap_or_else(PoisonError::into_inner)
                    .expect("every job produced a result")
            })
            .collect()
    }
}

/// Next job for worker `me`: own deque front first (submission order,
/// cache-warm), then steal from the *back* of the peers' deques so two
/// hungry workers contend for opposite ends.
fn next_job(me: usize, deques: &[JobDeque]) -> Option<usize> {
    if let Some(i) = lock(&deques[me]).pop_front() {
        return Some(i);
    }
    let w = deques.len();
    for k in 1..w {
        if let Some(i) = lock(&deques[(me + k) % w]).pop_back() {
            return Some(i);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn one_worker_equals_sequential() {
        let order = Mutex::new(Vec::new());
        let out = Pool::new(1).run(10, |i| {
            lock(&order).push(i);
            i * i
        });
        assert_eq!(out, (0..10).map(|i| i * i).collect::<Vec<_>>());
        // Inline path: jobs execute in submission order on this thread.
        assert_eq!(*lock(&order), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn results_are_ordered_under_contention() {
        // Stagger runtimes so completion order differs from submission
        // order; results must still land by job index.
        let out = Pool::new(8).run(64, |i| {
            std::thread::sleep(Duration::from_millis(((i * 13) % 7) as u64));
            i
        });
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let hits = AtomicUsize::new(0);
        let out = Pool::new(4).run(100, |i| {
            hits.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        assert_eq!(out.len(), 100);
    }

    #[test]
    fn worker_panics_propagate_to_the_caller() {
        let pool = Pool::new(4);
        let r = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, |i| {
                if i == 7 {
                    panic!("job 7 exploded");
                }
                i
            })
        }));
        let payload = r.expect_err("the job panic must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("job 7 exploded"), "payload: {msg:?}");
    }

    #[test]
    fn zero_workers_resolves_to_auto() {
        assert!(Pool::new(0).workers() >= 1);
        assert_eq!(Pool::new(3).workers(), 3);
    }

    #[test]
    fn empty_input_yields_empty_output() {
        let out: Vec<usize> = Pool::new(4).run(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs_is_fine() {
        let out = Pool::new(32).run(3, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3]);
    }
}
