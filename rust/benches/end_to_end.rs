//! End-to-end benches — one per paper table/figure family, each running the
//! full experiment pipeline (workload generation → scheduling → executors →
//! metrics) under the DES engine and reporting wall time:
//!
//! * Fig 8/9  — DEMS + baselines across the six emulation workloads.
//! * Fig 10   — the E+C → DEM → DEMS ablation.
//! * Fig 11/12 — DEMS-A under shaped latency / replayed 4G bandwidth.
//! * Fig 13   — weak scaling to 28 edges.
//! * Fig 14/15 + Table 2 — GEMS on WL1/WL2.
//! * Fig 17/18 — the field workload + navigation coupling.
//! * queue — event-core micro-bench: the time-wheel `EventQueue` vs the
//!   retired binary-heap reference on a 10⁶-op DES churn loop.
//!
//! CLI (see `benchutil`): `--quick` for the CI smoke mode, `--json
//! [--out DIR]` to write `BENCH_end_to_end.json` — the file the
//! `bench-smoke` CI job uploads and gates regressions on (docs/PERF.md).

use ocularone::benchutil::{black_box, BenchSuite};
use ocularone::exec::CloudExecModel;
use ocularone::fleet::Workload;
use ocularone::model::{orin_field, DnnKind, GemsWorkload};
use ocularone::nav;
use ocularone::net::{mobility_trace, LognormalWan, TraceBandwidth,
                     TrapeziumLatency};
use ocularone::platform::Platform;
use ocularone::policy::Policy;
use ocularone::sim;
use ocularone::time::{ms, secs};

fn wan() -> CloudExecModel {
    CloudExecModel::new(Box::new(LognormalWan::default()))
}

fn main() {
    let mut suite = BenchSuite::new("end_to_end");
    println!("== end-to-end experiment benches (wall time per full run) ==");

    // Fig 8: one 300 s run per workload, DEMS vs the strongest baseline.
    for wl in Workload::fig8_all() {
        for policy in [Policy::edf_ec(), Policy::dems()] {
            let name =
                format!("fig8 {} [{}] 300s run", wl.name, policy.kind.name());
            let wl2 = wl.clone();
            suite.bench(&name, 1200, || {
                let p = Platform::new(policy.clone(), wl2.models.clone(),
                                      wan(), 3);
                black_box(sim::run(p, &wl2, 3));
            });
            // Engine-throughput gauge: the run is deterministic, so one
            // un-timed replay yields the per-iteration event count and
            // the JSON row gains events + events/sec for the CI gate.
            let p = Platform::new(policy.clone(), wl2.models.clone(),
                                  wan(), 3);
            suite.annotate_events(
                sim::run(p, &wl2, 3).events_processed,
            );
        }
    }

    // Fig 10 ablation chain on the stress workload.
    {
        let wl = Workload::emulation(4, true);
        for policy in [Policy::edf_ec(), Policy::dem(), Policy::dems()] {
            let name = format!("fig10 4D-A [{}]", policy.kind.name());
            suite.bench(&name, 1000, || {
                let p = Platform::new(policy.clone(), wl.models.clone(),
                                      wan(), 5);
                black_box(sim::run(p, &wl, 5));
            });
        }
    }

    // Fig 11: variability studies.
    {
        let wl = Workload::emulation(4, false);
        suite.bench("fig11 latency-shaped [DEMS-A]", 1000, || {
            let cloud = CloudExecModel::new(Box::new(
                TrapeziumLatency::paper_default(LognormalWan::default()),
            ));
            let p = Platform::new(Policy::dems_a(), wl.models.clone(),
                                  cloud, 9);
            black_box(sim::run(p, &wl, 9));
        });
        suite.bench("fig11 bandwidth-trace [DEMS-A]", 1000, || {
            let cloud = CloudExecModel::new(Box::new(TraceBandwidth {
                base: LognormalWan::default(),
                samples: mobility_trace(3, 300),
                period: secs(1),
            }));
            let p = Platform::new(Policy::dems_a(), wl.models.clone(),
                                  cloud, 9);
            black_box(sim::run(p, &wl, 9));
        });
    }

    // Fig 13: a full 28-edge weak-scaling sweep.
    {
        let wl = Workload::emulation(3, false);
        suite.bench("fig13 28-edge sweep [DEMS]", 3000, || {
            let mut total = 0.0;
            for e in 0..28u64 {
                let p = Platform::new(Policy::dems(), wl.models.clone(),
                                      wan(), 11 ^ e);
                total += sim::run(p, &wl, 11 ^ e).qos_utility();
            }
            black_box(total);
        });
    }

    // Fig 14 / Table 2: GEMS workloads.
    for wlk in [GemsWorkload::Wl1, GemsWorkload::Wl2] {
        let wl = Workload::gems(wlk, 0.9);
        let name = format!("fig14 {} [GEMS]", wl.name);
        suite.bench(&name, 1000, || {
            let p = Platform::new(Policy::gems(false), wl.models.clone(),
                                  wan(), 13);
            black_box(sim::run(p, &wl, 13));
        });
    }

    // Fig 17/18: field workload + navigation flight.
    {
        let wl = Workload::field(30, orin_field());
        suite.bench("fig17 field 30fps + nav [GEMS]", 1500, || {
            let mut p = Platform::new(Policy::gems(false), wl.models.clone(),
                                      wan(), 17);
            p.metrics.record_completions = true;
            let m = sim::run(p, &wl, 17);
            let events: Vec<nav::TrackingEvent> = m
                .completions
                .iter()
                .filter(|c| c.model == DnnKind::Hv)
                .map(|c| nav::TrackingEvent {
                    at: c.at,
                    success: c.success && c.latency <= ms(300),
                })
                .collect();
            black_box(nav::fly(&events, m.duration, 17));
        });
    }

    // Event-core micro-bench: the time-wheel vs the retired binary-heap
    // reference on a synthetic DES churn loop — preload a working set,
    // then 10⁶ pop→push cycles whose inter-event gaps match the
    // simulator's shape (segment cadence + jitter, so events land a few
    // dozen wheel buckets ahead). Deliberately NOT `fig8`-prefixed: the
    // rows inform the JSON artifact but the regression gate stays on the
    // engine-level fig8 family, which is what users actually feel.
    {
        use ocularone::rng::Rng;
        use ocularone::sim::{Event, EventQueue, HeapQueue};

        const PRELOAD: u64 = 10_000;
        const OPS: u64 = 1_000_000;

        suite.bench("queue wheel 1e6 pop/push churn", 2500, || {
            let mut q = EventQueue::new();
            let mut rng = Rng::new(0x0BE7_C0DE);
            for i in 0..PRELOAD {
                q.push(rng.below(1_000_000) as u64,
                       Event::Segment { drone: 0, tick: i });
            }
            let mut now = 0u64;
            for i in 0..OPS {
                let (t, _) = q.pop().expect("churn keeps the queue loaded");
                now = t;
                q.push(now + 33_000 + rng.below(200_000) as u64,
                       Event::Segment { drone: 1, tick: i });
            }
            black_box(now);
        });
        suite.annotate_events(OPS);

        suite.bench("queue heap 1e6 pop/push churn (reference)", 2500, || {
            let mut q = HeapQueue::new();
            let mut rng = Rng::new(0x0BE7_C0DE);
            for i in 0..PRELOAD {
                q.push(rng.below(1_000_000) as u64,
                       Event::Segment { drone: 0, tick: i });
            }
            let mut now = 0u64;
            for i in 0..OPS {
                let (t, _) = q.pop().expect("churn keeps the queue loaded");
                now = t;
                q.push(now + 33_000 + rng.below(200_000) as u64,
                       Event::Segment { drone: 1, tick: i });
            }
            black_box(now);
        });
        suite.annotate_events(OPS);
    }

    suite.finish().expect("write BENCH_end_to_end.json");
}
