//! PJRT runtime benches: per-model inference latency of the compiled
//! L1/L2 artifacts (the real request-path cost), plus Literal packing
//! overhead. Skips gracefully when `make artifacts` has not run.

use ocularone::benchutil::{bench, black_box};
use ocularone::runtime::Runtime;

fn main() {
    println!("== PJRT runtime benches ==");
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping: {e}");
            return;
        }
    };
    println!("platform: {}", rt.platform_name());
    for kind in rt.kinds() {
        let model = rt.model(kind).unwrap();
        let frame = rt.synth_frame(kind, 3).unwrap();
        // Warm once outside the timer.
        let _ = model.infer(&frame).unwrap();
        let name = format!("infer [{}]", kind.name());
        bench(&name, 1500, || {
            black_box(model.infer(&frame).unwrap());
        });
    }
    // Frame synthesis (input packing path of the fleet emulator).
    {
        let kind = rt.kinds()[0];
        bench("synth_frame 64x64x3", 300, || {
            black_box(rt.synth_frame(kind, 5).unwrap());
        });
    }
}
