//! Scheduler hot-path microbenchmarks: the per-task decision cost of each
//! policy, queue operations, the DES engine throughput, and the dispatch
//! cost of the pluggable-scheduler API (flag-branch static dispatch vs
//! `Box<dyn Scheduler>`). These are the L3 §Perf numbers in EXPERIMENTS.md
//! (target: decision ≪ 1 µs — far off the request path's millisecond
//! budgets).
//!
//! CLI (see `benchutil`): `--quick` for the CI smoke mode, `--json
//! [--out DIR]` to write `BENCH_scheduler.json`.

use ocularone::benchutil::{black_box, BenchSuite};
use ocularone::exec::CloudExecModel;
use ocularone::fleet::Workload;
use ocularone::model::{table1, DnnKind};
use ocularone::net::ConstantNet;
use ocularone::platform::Platform;
use ocularone::policy::Policy;
use ocularone::queues::{EdgeOrder, EdgeQueue};
use ocularone::rng::Rng;
use ocularone::sched::{FlagBranchScheduler, Scheduler};
use ocularone::sim::EventQueue;
use ocularone::task::{Task, VideoSegment};
use ocularone::time::ms;

fn cloud() -> CloudExecModel {
    CloudExecModel::new(Box::new(ConstantNet {
        latency: ms(40),
        bandwidth: 25.0e6,
    }))
}

fn mktask(id: u64, model: DnnKind, at: u64) -> Task {
    Task {
        id,
        model,
        segment: VideoSegment { id, drone: 0, created_at: at, bytes: 38_000 },
        pipeline: None,
    }
}

/// Steady-state submit stream against a live platform (≈24 tasks/s, the
/// 4D-A arrival rate), draining events so queues don't grow unboundedly.
/// Generic over the scheduler so it measures both dispatch modes.
fn bench_submit_stream<S: Scheduler>(suite: &mut BenchSuite, name: &str,
                                     mut platform: Platform<S>) {
    let mut q = EventQueue::new();
    let mut now = 0u64;
    let mut id = 0u64;
    let kinds = DnnKind::ALL;
    suite.bench(name, 300, move || {
        id += 1;
        now += 41_000; // ≈24 tasks/s
        let task = mktask(id, kinds[(id % 6) as usize], now);
        platform.submit_task(now, task, &mut q);
        while let Some((t, ev)) = q.pop() {
            match ev {
                ocularone::sim::Event::EdgeDone => {
                    platform.on_edge_done(t, &mut q)
                }
                ocularone::sim::Event::CloudTrigger => {
                    platform.on_cloud_trigger(t, &mut q)
                }
                ocularone::sim::Event::CloudDone { key } => {
                    platform.on_cloud_done(t, key, &mut q)
                }
                _ => {}
            }
            if q.len() > 256 {
                break;
            }
        }
    });
}

fn main() {
    let mut suite = BenchSuite::new("scheduler");
    println!("== scheduler microbenchmarks ==");

    // Raw queue ops at a realistic depth (~24 queued tasks = 4D-A burst).
    {
        let mut q = EdgeQueue::new(EdgeOrder::Edf);
        let mut rng = Rng::new(1);
        let mut id = 0u64;
        suite.bench("edge_queue insert+pop (depth ~24)", 300, || {
            while q.len() < 24 {
                id += 1;
                let dl = ms(500 + (rng.next_u64() % 500));
                q.insert(mktask(id, DnnKind::Hv, 0), dl, ms(174), 1.0);
            }
            black_box(q.pop());
        });
    }
    {
        let mut q = EdgeQueue::new(EdgeOrder::Edf);
        for i in 0..24 {
            q.insert(mktask(i, DnnKind::Hv, 0), ms(500 + i * 20), ms(174),
                     1.0);
        }
        suite.bench("probe_insert feasibility scan (24 deep)", 300, || {
            black_box(q.probe_insert(ms(700), ms(174), 1.0, 0));
        });
    }

    // Per-task admission decision for each policy, steady-state 4D-A-like
    // arrival stream against a live platform (Box<dyn Scheduler> path).
    for policy in [
        Policy::edf_ec(),
        Policy::dem(),
        Policy::dems(),
        Policy::dems_a(),
        Policy::gems(false),
        Policy::sota1(),
        Policy::sota2(),
    ] {
        let name = format!("submit_task [{}]", policy.kind.name());
        let platform = Platform::new(policy, table1(), cloud(), 42);
        bench_submit_stream(&mut suite, &name, platform);
    }

    // Dispatch-overhead comparison on the hot submit/steal path: the same
    // DEMS decisions routed through a static flag-branch match vs the
    // boxed trait object. The redesign must not regress this path.
    {
        let dems = Policy::dems();
        let flat = Platform::with_scheduler(
            FlagBranchScheduler::new(),
            dems.clone(),
            table1(),
            cloud(),
            42,
        );
        bench_submit_stream(&mut suite,
                            "submit_task [DEMS, flag-branch dispatch]",
                            flat);
        let boxed = Platform::new(dems, table1(), cloud(), 42);
        bench_submit_stream(&mut suite,
                            "submit_task [DEMS, Box<dyn Scheduler>]",
                            boxed);
    }

    // Same comparison over a full 300 s 3D-A run (DES engine included).
    {
        let wl = Workload::emulation(3, true);
        let wl2 = wl.clone();
        suite.bench("full 300s 3D-A sim [DEMS, flag-branch dispatch]", 2000,
                    move || {
                        let p = Platform::with_scheduler(
                            FlagBranchScheduler::new(),
                            Policy::dems(),
                            wl2.models.clone(),
                            cloud(),
                            7,
                        );
                        black_box(ocularone::sim::run(p, &wl2, 7));
                    });
        let wl3 = wl.clone();
        suite.bench("full 300s 3D-A sim [DEMS, Box<dyn Scheduler>]", 2000,
                    move || {
                        let p = Platform::new(Policy::dems(),
                                              wl3.models.clone(),
                                              cloud(), 7);
                        black_box(ocularone::sim::run(p, &wl3, 7));
                    });
    }

    // Full-workload simulated seconds per wall second (the DES engine).
    {
        let wl = Workload::emulation(4, true);
        suite.bench("full 300s 4D-A sim [DEMS]", 2000, || {
            let platform =
                Platform::new(Policy::dems(), wl.models.clone(), cloud(), 7);
            black_box(ocularone::sim::run(platform, &wl, 7));
        });
    }

    // FaaS backend container lifecycle on the invoke/complete hot path:
    // steady-state warm-pool hits, the all-cold keep-alive-expired path,
    // and the throttle fast path (see src/cloud/faas.rs).
    {
        use ocularone::cloud::{Attempt, CloudBackend, FaasBackend,
                               FaasConfig};
        let mk_net = || {
            Box::new(ConstantNet { latency: ms(40), bandwidth: 25.0e6 })
        };
        let m = table1()[0].clone();
        let mut warm = FaasBackend::new(FaasConfig::default(), mk_net());
        let mut rng = Rng::new(9);
        let mut now = 0u64;
        suite.bench("faas_backend invoke+complete (warm pool)", 300,
                    move || {
                        now += 1_000;
                        if let Attempt::Run(inv) =
                            warm.invoke(&m, now, 38_000, 0, &mut rng)
                        {
                            warm.complete(m.kind, inv.token,
                                          now + inv.duration);
                        }
                    });
        let m = table1()[0].clone();
        let mut cold = FaasBackend::new(
            FaasConfig { keep_alive: 0, ..FaasConfig::default() },
            mk_net(),
        );
        let mut rng = Rng::new(10);
        let mut now = 0u64;
        suite.bench("faas_backend invoke+complete (every-cold)", 300,
                    move || {
                        now += 1_000;
                        if let Attempt::Run(inv) =
                            cold.invoke(&m, now, 38_000, 0, &mut rng)
                        {
                            cold.complete(m.kind, inv.token,
                                          now + inv.duration);
                        }
                    });
        let m = table1()[0].clone();
        let mut full = FaasBackend::new(
            FaasConfig { concurrency: 0, ..FaasConfig::default() },
            mk_net(),
        );
        let mut rng = Rng::new(11);
        suite.bench("faas_backend throttle fast path", 300, move || {
            black_box(full.invoke(&m, 0, 38_000, 0, &mut rng));
        });
    }

    // Full 300 s 3D-A run against the FaaS backend (container lifecycle
    // + billing on every cloud dispatch) vs the simple-sampler runs
    // above — the subsystem's end-to-end overhead in one number.
    {
        use ocularone::cluster::Cluster;
        use ocularone::scenario::CloudSpec;
        use ocularone::time::secs;
        let wl = Workload::emulation(3, true);
        suite.bench("full 300s 3D-A sim [DEMS-A, faas backend]", 2000,
                    move || {
                        let spec = CloudSpec::faas(secs(300), 64);
                        let cm = Cluster::single(&Policy::dems_a(), &wl, 7,
                                                 spec.build())
                            .run();
                        black_box(cm);
                    });
    }

    // Resilience-layer hot paths (src/resilience.rs), gated in CI via
    // `check_bench_regression.py --prefix resilience`: the circuit
    // breaker's per-dispatch gate+record cost, the degradation
    // controller's per-start observe cost, and the end-to-end overhead
    // of a fully armed run vs the plain FaaS run above.
    {
        use ocularone::resilience::{CircuitBreaker, DegradeController,
                                    ResilienceSpec};
        let spec = ResilienceSpec::full();
        let mut breaker = CircuitBreaker::new(&spec);
        let mut now = 0u64;
        suite.bench("resilience breaker gate+record hot path", 300,
                    move || {
                        now += 1_000;
                        let g = breaker.gate(now);
                        black_box(g);
                        // 1-in-4 failures hovers below the trip
                        // threshold, so both window rolls and state
                        // checks stay on the measured path.
                        breaker.record(now, now % 4_000 == 0, false);
                    });
        let mut degrade = DegradeController::new(&spec);
        let mut now = 0u64;
        suite.bench("resilience degrade observe hot path", 300, move || {
            now += 1_000;
            degrade.observe(now, (now / 1_000 % 12) as usize, false);
            black_box(degrade.lite());
        });
        use ocularone::cluster::Cluster;
        use ocularone::scenario::CloudSpec;
        use ocularone::time::secs;
        let wl = Workload::emulation(3, true);
        suite.bench("resilience full 300s 3D-A sim [DEMS-A armed, faas]",
                    2000, move || {
                        let spec = CloudSpec::faas(secs(300), 64);
                        let policy = Policy::dems_a()
                            .with_resilience(ResilienceSpec::full());
                        let cm = Cluster::single(&policy, &wl, 7,
                                                 spec.build())
                            .run();
                        black_box(cm);
                    });
    }

    // The parallel sweep engine itself: a 12-cell grid (3 workloads × 2
    // policies × 2 seeds) on 1 worker vs all cores — the `--jobs`
    // speedup knob in one number.
    {
        use ocularone::scenario::Scenario;
        use ocularone::time::secs;
        let grid = || {
            Scenario::new("bench-grid", "bench grid")
                .workload(Workload::emulation(2, false)
                    .with_duration(secs(60)))
                .workload(Workload::emulation(3, false)
                    .with_duration(secs(60)))
                .workload(Workload::emulation(2, true)
                    .with_duration(secs(60)))
                .policies(vec![Policy::edf_ec(), Policy::dems()])
                .edges(2)
                .seeds(2)
        };
        let g1 = grid();
        suite.bench("sweep 12-cell grid [--jobs 1]", 2000, move || {
            black_box(g1.run_jobs(7, 1).expect("grid runs"));
        });
        let gn = grid();
        suite.bench("sweep 12-cell grid [--jobs 0 = all cores]", 2000,
                    move || {
                        black_box(gn.run_jobs(7, 0).expect("grid runs"));
                    });
    }

    suite.finish().expect("write BENCH_scheduler.json");
}
